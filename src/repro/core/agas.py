"""AGAS: the Active Global Address Space.

The paper (Sec. II) motivates AGAS by dynamic AMR: "the requirements for
dynamic load-balancing ... define the necessity for a single global
address space"; unlike PGAS systems (UPC/X10/Chapel) the *active* part
means objects can move without their global name changing.

Here AGAS is a directory mapping immutable global ids (gids) to
(locality, slot) pairs, where a slot indexes a fixed-capacity local
object pool on each locality.  On device, the pools are the leading axis
of block-batched arrays, so an AGAS "lookup" compiles to a gather index
and a "migration" compiles to a permutation (gather/scatter or
ppermute) — nothing dynamic survives to run time, which is this
framework's analogue of the paper's Sec. V proposal to accelerate AGAS
lookups in hardware.

Localities need not be homogeneous: `pool_capacity` may be a
per-locality sequence, and each locality can carry an integer *tier*
tag (`core/percolation.py` uses 0 = device HBM, 1 = host DRAM).  An
object's global name is stable across a move between tiers exactly as
it is across a move between same-tier localities — percolation
(DESIGN.md §4d) is AGAS migration along the vertical memory axis.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.localities import LocalityDomain
from repro.obs import trace as _trace


class AGASError(RuntimeError):
    pass


@dataclasses.dataclass(frozen=True)
class GlobalAddress:
    """Immutable first-class name of an object (block, LCO, thread...)."""

    gid: int
    space: str = "default"

    def __index__(self) -> int:
        return self.gid


class AGAS:
    """Directory of global names -> (locality, slot) with migration.

    The directory also keeps per-locality free lists so allocation is
    O(1); `checkpoint_state`/`restore_state` make the directory itself a
    first-class checkpointable object (needed for elastic restart).
    """

    def __init__(self, domain: LocalityDomain, pool_capacity,
                 space: str = "default",
                 tiers: Optional[Sequence[int]] = None):
        self.domain = domain
        if isinstance(pool_capacity, (int, np.integer)):
            self.capacities = [int(pool_capacity)] * len(domain)
        else:
            if len(pool_capacity) != len(domain):
                raise ValueError(
                    f"{len(pool_capacity)} capacities for "
                    f"{len(domain)} localities")
            self.capacities = [int(c) for c in pool_capacity]
        # uniform-pool compat: `capacity` is THE per-locality capacity
        # when the pools are homogeneous, the largest otherwise
        self.capacity = max(self.capacities, default=0)
        if tiers is None:
            tiers = [0] * len(domain)
        if len(tiers) != len(domain):
            raise ValueError(
                f"{len(tiers)} tier tags for {len(domain)} localities")
        self.tiers = [int(t) for t in tiers]
        self.space = space
        self._gids = itertools.count()
        self._where: Dict[int, Tuple[int, int]] = {}
        self._free: List[List[int]] = [
            list(range(c)) for c in self.capacities
        ]
        self._residents: List[set] = [set() for _ in range(len(domain))]
        self._inactive: set = set()
        self.migrations = 0  # counter surfaced as a performance counter

    # -- tiers -------------------------------------------------------------
    def tier_of(self, locality: int) -> int:
        return self.tiers[locality]

    def localities_in_tier(self, tier: int) -> List[int]:
        return [l for l, t in enumerate(self.tiers) if t == tier]

    # -- locality lifecycle ------------------------------------------------
    def deactivate(self, locality: int) -> None:
        """Retire a locality from placement (failure or planned drain).

        Allocation, migration targets and `least_loaded` refuse it
        until `activate`.  Residents are NOT touched — the caller
        decides their fate (kill sweep, evacuation); `free` keeps
        working on a retired locality so a sweep can return slots,
        and a later `activate` finds the free list intact (elastic
        re-join without rebuilding the directory).
        """
        self._inactive.add(int(locality))

    def activate(self, locality: int) -> None:
        """Re-admit a retired locality to placement (elastic join)."""
        self._inactive.discard(int(locality))

    def is_active(self, locality: int) -> bool:
        return locality not in self._inactive

    # -- allocation --------------------------------------------------------
    def allocate(self, locality: int) -> GlobalAddress:
        if locality in self._inactive:
            raise AGASError(f"locality {locality} is retired")
        if not self._free[locality]:
            raise AGASError(
                f"locality {locality} pool exhausted "
                f"(capacity {self.capacities[locality]})"
            )
        slot = self._free[locality].pop()
        gid = next(self._gids)
        self._where[gid] = (locality, slot)
        self._residents[locality].add(gid)
        return GlobalAddress(gid, self.space)

    def allocate_many(self, locality: int, n: int) -> List[GlobalAddress]:
        return [self.allocate(locality) for _ in range(n)]

    def free(self, addr: GlobalAddress) -> None:
        loc, slot = self._where.pop(addr.gid)
        self._residents[loc].discard(addr.gid)
        self._free[loc].append(slot)

    # -- lookup --------------------------------------------------------------
    def lookup(self, addr: GlobalAddress) -> Tuple[int, int]:
        """gid -> (locality, slot).  Raises on dangling references."""
        try:
            return self._where[addr.gid]
        except KeyError:
            raise AGASError(f"dangling global address {addr.gid}") from None

    def locality_of(self, addr: GlobalAddress) -> int:
        return self.lookup(addr)[0]

    def slot_of(self, addr: GlobalAddress) -> int:
        return self.lookup(addr)[1]

    def is_local(self, addr: GlobalAddress, locality: int) -> bool:
        """The action-manager query: local action or parcel? (paper Fig 1)."""
        return self.locality_of(addr) == locality

    def residents(self, locality: int) -> set:
        return set(self._residents[locality])

    def resident_on(self, gid: int, locality: int) -> bool:
        """Is `gid` currently homed on `locality`?  False for freed
        (dangling) gids — a sweep-safe residency probe: a kill sweep's
        own evictions may move or drop pages it has not reached yet."""
        loc_slot = self._where.get(gid)
        return loc_slot is not None and loc_slot[0] == locality

    def free_count(self, locality: int) -> int:
        """Free pool slots on one locality (the allocator's load signal)."""
        return len(self._free[locality])

    def least_loaded(self, tier: Optional[int] = None) -> int:
        """Locality with the most free slots (ties -> lowest id).

        The locality-aware allocation policy: new objects land where
        capacity is, which keeps the per-locality pools balanced without
        a central planner (the HPX local-first/least-loaded placement
        the sharded KV page pool uses).  `tier` restricts the choice to
        one memory tier — a tiered pool allocates fresh objects in fast
        memory only; the slow tier is reached by explicit percolation.
        """
        cands = range(len(self.domain)) if tier is None \
            else self.localities_in_tier(tier)
        cands = [l for l in cands if l not in self._inactive]
        if not cands:
            raise AGASError(f"no active locality in tier {tier}")
        return max(cands, key=lambda l: (self.free_count(l), -l))

    # -- migration -----------------------------------------------------------
    def migrate(self, addr: GlobalAddress, new_locality: int) -> Tuple[int, int]:
        """Move an object; its global name is unchanged (the AGAS promise).

        Returns (old_locality, new_slot).  The caller is responsible for
        moving the payload (see core/parcels.migration_plan).
        """
        old_loc, old_slot = self.lookup(addr)
        if old_loc == new_locality:
            return old_loc, old_slot
        if new_locality in self._inactive:
            raise AGASError(
                f"migration target {new_locality} is retired")
        if not self._free[new_locality]:
            raise AGASError(f"migration target {new_locality} pool full")
        new_slot = self._free[new_locality].pop()
        self._free[old_loc].append(old_slot)
        self._residents[old_loc].discard(addr.gid)
        self._residents[new_locality].add(addr.gid)
        self._where[addr.gid] = (new_locality, new_slot)
        self.migrations += 1
        _trace.GLOBAL.instant("agas", "migrate", gid=addr.gid,
                              src=old_loc, dst=new_locality)
        return old_loc, new_slot

    # -- bulk views (compiled into gather indices) ----------------------------
    def placement_arrays(self, addrs: Sequence[GlobalAddress]
                         ) -> Tuple[np.ndarray, np.ndarray]:
        """(localities, slots) int32 arrays for a list of gids, in order."""
        locs = np.empty(len(addrs), np.int32)
        slots = np.empty(len(addrs), np.int32)
        for i, a in enumerate(addrs):
            locs[i], slots[i] = self.lookup(a)
        return locs, slots

    def load(self) -> np.ndarray:
        """Objects resident per locality (the load-balance signal)."""
        return np.array([len(r) for r in self._residents], np.int64)

    # -- checkpoint / elastic restore ----------------------------------------
    def checkpoint_state(self) -> dict:
        return {
            "capacity": self.capacity,
            "capacities": list(self.capacities),
            "tiers": list(self.tiers),
            "space": self.space,
            "n_localities": len(self.domain),
            "where": dict(self._where),
            "next_gid": next(self._gids),  # consumes one id; fine for ckpt
        }

    @staticmethod
    def restore_state(state: dict, domain: LocalityDomain,
                      remap: Optional[Dict[int, int]] = None) -> "AGAS":
        """Rebuild a directory, optionally remapping localities.

        `remap` supports elastic restore: a checkpoint taken on P
        localities can be restored onto P' by providing old->new ids
        (defaults to `old % P'`, the round-robin fold).  Restoring onto
        a different locality count keeps the UNIFORM capacity (tier
        tags do not survive a fold across counts).
        """
        caps = state.get("capacities")
        tiers = state.get("tiers")
        if caps is None or len(caps) != len(domain):
            caps = state["capacity"]
            tiers = None
        agas = AGAS(domain, caps, state["space"], tiers=tiers)
        n_new = len(domain)
        for gid, (loc, _slot) in sorted(state["where"].items()):
            new_loc = remap[loc] if remap else loc % n_new
            if not agas._free[new_loc]:
                raise AGASError(f"restore overflows locality {new_loc}")
            slot = agas._free[new_loc].pop()
            agas._where[gid] = (new_loc, slot)
            agas._residents[new_loc].add(gid)
        agas._gids = itertools.count(state["next_gid"])
        return agas


def balanced_placement(costs: Sequence[float], n_localities: int
                       ) -> List[int]:
    """LPT (longest-processing-time) static placement of objects.

    This is the *static* load balancer the compiled engine uses; the
    paper's emergent work-queue balancing is the dynamic complement
    (core/scheduler.py) and ft/straggler.py re-invokes this between
    compiled steps when measured load drifts.
    """
    order = sorted(range(len(costs)), key=lambda i: -costs[i])
    loads = np.zeros(n_localities)
    out = [0] * len(costs)
    for i in order:
        tgt = int(np.argmin(loads))
        out[i] = tgt
        loads[tgt] += costs[i]
    return out


def contiguous_placement(n_objects: int, n_localities: int) -> List[int]:
    """Block-contiguous placement (the MPI-style static decomposition)."""
    per = -(-n_objects // n_localities)
    return [min(i // per, n_localities - 1) for i in range(n_objects)]
