"""Paper Fig 6: 1-level AMR, 4 workers, with vs without global barrier.

"Cases without the global barrier were able to compute more timesteps
than cases with the global barrier in the same amount of time."  We fix
a wall-clock budget (the barrier run's makespan for N coarse steps) and
count the timesteps the dataflow run completes within it, plus the
converse makespan ratio.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro import amr
from repro.amr import taskgraph as tg
from repro.core import barrier_schedule, list_schedule


def run(n_points=256, n_coarse=8, grain=8, workers=4, verbose=True):
    prob = amr.WaveProblem(n_points=n_points, rmax=20.0,
                           amplitude=0.005)
    specs = amr.default_specs(prob, 2)   # 1 level of refinement
    wg = tg.build_window_graph(specs, n_coarse, grain)
    tg.assign_owners(wg, workers)
    ba = barrier_schedule(wg.graph, workers, overhead=4e-6,
                          barrier_cost=2e-5)
    df = list_schedule(wg.graph, workers, overhead=4e-6,
                       priority=lambda t: t.tid)
    # Fixed wall-clock budget strictly inside both runs (the paper's
    # "10 or 60 seconds of wall clock time").
    budget = 0.5 * ba.makespan
    f_ba = tg.timestep_front(wg, ba.finish, budget, prob.n_points)
    f_df = tg.timestep_front(wg, df.finish, budget, prob.n_points)
    if verbose:
        print(f"# fig6 budget={budget * 1e3:.3f}ms  "
              f"barrier mean steps={f_ba.mean():.2f}  "
              f"dataflow mean steps={f_df.mean():.2f}")
    emit("fig6_steps_in_budget_barrier", budget * 1e6,
         f"mean_steps={f_ba.mean():.3f}")
    emit("fig6_steps_in_budget_dataflow", df.makespan * 1e6,
         f"mean_steps={f_df.mean():.3f}")
    emit("fig6_makespan_ratio", ba.makespan / df.makespan * 100,
         "barrier_over_dataflow_pct")
    return f_ba.mean(), f_df.mean()


if __name__ == "__main__":
    run()
