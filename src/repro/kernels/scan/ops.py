"""Jitted public wrapper for the selective-scan kernel."""

from __future__ import annotations

import os
from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.scan.selective_scan import selective_scan


def _interpret_default() -> bool:
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "False")
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("chunk", "d_block"))
def selective_scan_op(da: jnp.ndarray, dbx: jnp.ndarray,
                      c: jnp.ndarray, *, chunk: int = 128,
                      d_block: int = 256) -> jnp.ndarray:
    return selective_scan(da, dbx, c, chunk=chunk, d_block=d_block,
                          interpret=_interpret_default())
