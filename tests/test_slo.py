"""Request-level SLO/goodput observability (DESIGN.md §10):
flight-recorder timelines + phase decomposition, deadline
classification with per-phase blame, verdict streaming into the
metrics registry, the Prometheus/JSONL exporters, and per-role span
attribution — units plus a recorded disagg-engine integration run."""

import json

import numpy as np
import pytest
import jax

import repro.configs as configs
from repro.models import transformer as T
from repro.obs.attribution import attribute_roles
from repro.obs.export import (JsonlExporter, parse_prometheus,
                              prom_name, read_jsonl, to_prometheus,
                              verify_roundtrip)
from repro.obs.metrics import MetricsRegistry
from repro.obs.slo import (BLAME_PHASES, NULL_RECORDER,
                           FlightRecorder, build_report, classify,
                           derive_phases, record_verdict)
from repro.obs.trace import Tracer, set_global
from repro.serving.engine import Request, make_engine
from repro.serving.types import Completion


class ManualClock:
    """Deterministic recorder clock; the test advances ``t``."""

    def __init__(self, t=0.0):
        self.t = t

    def __call__(self):
        return self.t


def _recorder(clk=None):
    return FlightRecorder(clock=clk or ManualClock())


# -- flight recorder: timelines, retention, null no-op ----------------

def test_recorder_timeline_append_order_and_args():
    clk = ManualClock()
    fr = _recorder(clk)
    fr.event(1, "submit", prompt_len=12)
    clk.t = 1.0
    fr.event(1, "bind", slot=3)
    clk.t = 2.0
    fr.event(1, "prefill_chunk", dur=0.5, take=32)
    tl = fr.timeline(1)
    assert [e.name for e in tl] == ["submit", "bind", "prefill_chunk"]
    assert tl[0].t == 0.0 and tl[0].args["prompt_len"] == 12
    assert tl[2].dur == 0.5
    assert tl[0].dur is None          # point events have no dur
    assert fr.timeline(99) == ()
    assert fr.rids() == [1]


def test_recorder_explicit_timestamp_overrides_clock():
    fr = _recorder(ManualClock(5.0))
    fr.event(0, "submit", t=1.25)
    assert fr.timeline(0)[0].t == 1.25


def test_recorder_retention_evicts_oldest_finished_only():
    fr = FlightRecorder(retain=2, clock=ManualClock())
    for rid in range(4):
        fr.event(rid, "submit")
        fr.event(rid, "finish")
    fr.event(9, "submit")             # live: never evicted
    assert fr.rids() == [2, 3, 9]


def test_recorder_json_dump_roundtrip(tmp_path):
    clk = ManualClock()
    fr = _recorder(clk)
    fr.event(7, "submit")
    clk.t = 1.0
    fr.event(7, "bind", slot=0)
    clk.t = 2.0
    fr.event(7, "first_token")
    clk.t = 3.0
    fr.event(7, "finish")
    path = fr.dump_json(str(tmp_path / "fr.json"))
    with open(path) as f:
        loaded = json.load(f)
    evs = loaded["requests"]["7"]["events"]
    assert [e["name"] for e in evs] == ["submit", "bind",
                                        "first_token", "finish"]
    assert loaded["requests"]["7"]["phases"]["complete"] is True
    fr.clear()
    assert fr.rids() == []


def test_null_recorder_is_inert():
    assert NULL_RECORDER.enabled is False
    assert NULL_RECORDER.event(0, "submit") is None
    assert NULL_RECORDER.timeline(0) == ()
    assert NULL_RECORDER.rids() == ()
    assert NULL_RECORDER.phases(0) == {}


# -- phase decomposition ----------------------------------------------

def _tl(fr, rid=0):
    return fr.timeline(rid)


def test_derive_phases_simple_lifecycle():
    clk = ManualClock()
    fr = _recorder(clk)
    fr.event(0, "submit")
    clk.t = 1.0                        # 1s queued
    fr.event(0, "bind", slot=0)
    clk.t = 3.0                        # chunk ran 2.0-3.0
    fr.event(0, "prefill_chunk", dur=1.0)
    fr.event(0, "first_token")
    clk.t = 5.0                        # 2s decoding
    fr.event(0, "finish")
    ph = derive_phases(_tl(fr))
    assert ph["queue"] == pytest.approx(1.0)
    assert ph["prefill_exec"] == pytest.approx(1.0)
    assert ph["prefill_wait"] == pytest.approx(1.0)   # 1.0-2.0 gap
    assert ph["decode"] == pytest.approx(2.0)
    assert ph["preempted"] == 0.0
    assert ph["ttft_s"] == pytest.approx(3.0)
    assert ph["e2e_s"] == pytest.approx(5.0)
    assert ph["complete"] is True
    # phases tile the end-to-end window exactly
    assert (ph["queue"] + ph["prefill_exec"] + ph["prefill_wait"]
            + ph["decode"]) == pytest.approx(ph["e2e_s"])


def test_derive_phases_preemption_gap_splits_at_first_token():
    clk = ManualClock()
    fr = _recorder(clk)
    fr.event(0, "submit")
    fr.event(0, "bind", slot=0)
    clk.t = 1.0
    fr.event(0, "preempt", slot=0)     # pre-first gap 1.0-2.0
    clk.t = 2.0
    fr.event(0, "bind", slot=1)
    clk.t = 3.0
    fr.event(0, "first_token")
    clk.t = 4.0
    fr.event(0, "preempt", slot=1)     # decode-window gap 4.0-5.5
    clk.t = 5.5
    fr.event(0, "bind", slot=0)
    clk.t = 6.0
    fr.event(0, "finish")
    ph = derive_phases(_tl(fr))
    assert ph["preempted_pre_first"] == pytest.approx(1.0)
    assert ph["preempted"] == pytest.approx(2.5)
    assert ph["decode"] == pytest.approx(1.5)  # 3s window - 1.5s gap


def test_derive_phases_final_chunk_dur_lands_in_ttft_window():
    # events are stamped at op END; the final chunk samples the first
    # token INSIDE itself, so its dur must count as pre-first exec
    clk = ManualClock()
    fr = _recorder(clk)
    fr.event(0, "submit")
    fr.event(0, "bind", slot=0)
    clk.t = 2.0
    fr.event(0, "first_token")
    clk.t = 2.5                        # chunk 0.5-2.5, first token in it
    fr.event(0, "prefill_chunk", dur=2.0)
    clk.t = 3.0
    fr.event(0, "finish")
    ph = derive_phases(_tl(fr))
    assert ph["prefill_exec"] == pytest.approx(2.0)
    assert ph["prefill_exec_post"] == 0.0
    assert ph["prefill_wait"] == pytest.approx(0.0)


def test_derive_phases_partial_timelines():
    assert derive_phases(()) == {}
    clk = ManualClock()
    fr = _recorder(clk)
    fr.event(0, "submit")
    clk.t = 2.0
    fr.event(0, "bind", slot=0)        # never reached first token
    ph = derive_phases(_tl(fr))
    assert ph["queue"] == pytest.approx(2.0)
    assert ph["ttft_s"] is None
    assert ph["complete"] is False


# -- deadline classification ------------------------------------------

def _req(ttft=None, itl=None):
    return Request(0, np.arange(4, dtype=np.int32),
                   ttft_deadline_ms=ttft, itl_deadline_ms=itl)


def _comp(ttft_s=0.010, itl_s=(0.002, 0.003)):
    return Completion(rid=0, tokens=[1, 2, 3], prefill_s=0.0,
                      decode_s=0.0, ttft_s=ttft_s,
                      itl_s=list(itl_s))


def test_classify_untracked_request_never_counts():
    v = classify(_req(), _comp())
    assert v["tracked"] is False and v["met"] is False
    assert v["blame"] is None
    m = MetricsRegistry()
    record_verdict(m, v)
    assert "slo.requests" not in m.snapshot()


def test_classify_met_and_missed_deadlines():
    met = classify(_req(ttft=50.0, itl=50.0), _comp())
    assert met["met"] is True and met["blame"] is None
    miss = classify(_req(ttft=5.0, itl=50.0), _comp())
    assert miss["ttft_miss"] is True and miss["itl_miss"] is False
    assert miss["met"] is False
    assert miss["blame"] == "unattributed"   # no timeline given
    assert miss["ttft_ms"] == pytest.approx(10.0)
    itl = classify(_req(ttft=50.0, itl=1.0), _comp())
    assert itl["itl_miss"] is True
    # p95 of the itl list is checked, not the mean
    assert itl["itl_p95_ms"] == pytest.approx(
        float(np.percentile([0.002, 0.003], 95.0)) * 1e3)


def test_classify_blames_largest_ttft_contributor():
    clk = ManualClock()
    fr = _recorder(clk)
    fr.event(0, "submit")
    clk.t = 8.0                        # 8s queued ...
    fr.event(0, "bind", slot=0)
    clk.t = 9.0
    fr.event(0, "prefill_chunk", dur=1.0)   # ... 1s exec
    fr.event(0, "first_token")
    clk.t = 9.5
    fr.event(0, "finish")
    v = classify(_req(ttft=100.0), _comp(ttft_s=9.0),
                 timeline=_tl(fr))
    assert v["ttft_miss"] and v["blame"] == "queue"


def test_classify_blames_itl_on_decode_window():
    clk = ManualClock()
    fr = _recorder(clk)
    fr.event(0, "submit")
    fr.event(0, "bind", slot=0)
    clk.t = 0.1
    fr.event(0, "first_token")
    clk.t = 1.0
    fr.event(0, "preempt", slot=0)     # 4s mid-decode preemption gap
    clk.t = 5.0
    fr.event(0, "bind", slot=1)
    clk.t = 6.0
    fr.event(0, "finish")
    v = classify(_req(itl=1.0), _comp(itl_s=[2.0]),
                 timeline=_tl(fr))
    assert v["itl_miss"] and v["blame"] == "preempt"


# -- verdict streaming + report ---------------------------------------

def test_record_verdict_streams_goodput_and_blame():
    m = MetricsRegistry()
    record_verdict(m, classify(_req(ttft=50.0, itl=50.0), _comp()))
    record_verdict(m, classify(_req(ttft=5.0), _comp()))
    snap = m.snapshot()
    assert snap["slo.requests"] == 2
    assert snap["slo.met"] == 1
    assert snap["slo.ttft_misses"] == 1
    assert snap["slo.blame.unattributed"] == 1
    assert snap["slo.goodput"] == pytest.approx(0.5)


# -- Prometheus exporter ----------------------------------------------

def test_prom_name_sanitizes():
    assert prom_name("engine.ttft_ms") == "repro_engine_ttft_ms"
    assert prom_name("a.b-c d", prefix="x_") == "x_a_b_c_d"


def test_to_prometheus_golden():
    m = MetricsRegistry()
    m.counter("slo.requests").inc(3)
    m.gauge("slo.goodput").set(0.5)
    h = m.histogram("engine.ttft_ms")
    h.record(2.0)
    text = to_prometheus(m)
    snap = m.snapshot()
    assert text == (
        "# HELP repro_engine_ttft_ms engine.ttft_ms\n"
        "# TYPE repro_engine_ttft_ms summary\n"
        f'repro_engine_ttft_ms{{quantile="0.5"}} '
        f"{snap['engine.ttft_ms.p50']!r}\n"
        f'repro_engine_ttft_ms{{quantile="0.95"}} '
        f"{snap['engine.ttft_ms.p95']!r}\n"
        f'repro_engine_ttft_ms{{quantile="0.99"}} '
        f"{snap['engine.ttft_ms.p99']!r}\n"
        "repro_engine_ttft_ms_sum 2.0\n"
        "repro_engine_ttft_ms_count 1\n"
        "repro_engine_ttft_ms_min 2.0\n"
        "repro_engine_ttft_ms_max 2.0\n"
        "# HELP repro_slo_goodput slo.goodput\n"
        "# TYPE repro_slo_goodput gauge\n"
        "repro_slo_goodput 0.5\n"
        "# HELP repro_slo_requests_total slo.requests\n"
        "# TYPE repro_slo_requests_total counter\n"
        "repro_slo_requests_total 3\n")


def test_parse_prometheus_and_roundtrip():
    m = MetricsRegistry()
    m.counter("a.c").inc(7)
    m.gauge("b.g").set(1.25)
    for x in (1.0, 2.0, 4.0, 8.0):
        m.histogram("h.ms").record(x)
    text = to_prometheus(m)
    parsed = parse_prometheus(text)
    assert parsed["repro_a_c_total"] == 7.0
    assert parsed["repro_b_g"] == 1.25
    assert parsed["repro_h_ms_count"] == 4.0
    assert 'repro_h_ms{quantile="0.5"}' in parsed
    assert verify_roundtrip(m) == []
    with pytest.raises(ValueError):
        parse_prometheus("!!! not a sample\n")


def test_verify_roundtrip_catches_tampering():
    m = MetricsRegistry()
    m.counter("a.c").inc(7)
    text = to_prometheus(m).replace(" 7", " 8")
    problems = verify_roundtrip(m, text=text)
    assert problems and "repro_a_c_total" in problems[0]


# -- JSONL exporter ---------------------------------------------------

def test_jsonl_snapshots_deltas_and_sum_invariant(tmp_path):
    m = MetricsRegistry()
    path = str(tmp_path / "m.jsonl")
    c = m.counter("slo.requests")
    g = m.gauge("slo.goodput")
    clk = ManualClock(100.0)
    with JsonlExporter(m, path, clock=clk) as exp:
        c.inc(2)
        g.set(1.0)
        exp.snap(step=1)
        clk.t = 101.0
        c.inc(3)
        exp.snap(step=2)
        exp.snap(step=3)               # nothing changed: empty delta
        assert exp.records == 3
    recs = read_jsonl(path)
    assert [r["step"] for r in recs] == [1, 2, 3]
    assert recs[0]["t"] == 100.0
    # first delta is the full snapshot; later deltas only changes
    assert recs[0]["delta"] == recs[0]["metrics"]
    assert recs[1]["delta"] == {"slo.requests": 3}
    assert recs[2]["delta"] == {}
    assert recs[-1]["metrics"] == m.snapshot()
    # summing deltas over the file reconstructs the final snapshot —
    # except gauges, whose deltas are signed moves, summed from 0
    total = {}
    for r in recs:
        for k, v in r["delta"].items():
            total[k] = total.get(k, 0) + v
    assert total == {"slo.requests": 5, "slo.goodput": 1.0}
    assert total == recs[-1]["metrics"]


# -- role/locality span attribution -----------------------------------

def test_attribute_roles_buckets_by_span_name_and_locality():
    clk = ManualClock()
    tr = Tracer(capacity=64, clock=clk)
    with tr.span("engine", "step"):
        clk.t = 0.01
        with tr.span("engine", "prefill_chunk", kind="compute",
                     loc=0):
            clk.t = 0.05               # 40ms prefill @ loc0
        with tr.span("percolation", "handoff_stage", kind="copy",
                     loc=1):
            clk.t = 0.06               # 10ms handoff @ loc1
        with tr.span("engine", "decode_batch", kind="compute"):
            clk.t = 0.16               # 100ms decode, engine-local
        clk.t = 0.20
    rep = attribute_roles(tr.records())
    assert rep["steps"] == 1
    assert rep["wall_ms"] == pytest.approx(200.0)
    roles = rep["roles_ms"]
    assert roles["prefill"] == pytest.approx(40.0)
    assert roles["handoff"] == pytest.approx(10.0)
    assert roles["decode"] == pytest.approx(100.0)
    assert roles["other"] == pytest.approx(50.0)   # step self time
    locs = rep["localities_ms"]
    assert locs["loc0"] == pytest.approx(40.0)
    assert locs["loc1"] == pytest.approx(10.0)
    assert locs["engine"] == pytest.approx(150.0)
    assert rep["sum_residual"] <= 1e-9


# -- recorded engine integration --------------------------------------

def test_recorded_disagg_run_timeline_complete_and_reported():
    """Chunked+disagg+tiering run with recorder, tracer, and deadlines
    all on: every finished request's timeline must carry the full
    lifecycle, phases must tile TTFT, verdicts must land in stats()
    and build_report, and the exposition must round-trip."""
    cfg = configs.get_reduced("yi-6b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    tr = Tracer(capacity=1 << 15)
    eng = make_engine(params, cfg, engine="chunked", slots=4,
                      max_len=96, prefill_buckets=(32,), page_size=16,
                      n_pages=24, chunk_size=32, step_tokens=68,
                      kv_shards=2, tiering=True, host_pages=32,
                      disagg=True, tracer=tr, flight_recorder=True)
    rng = np.random.default_rng(3)
    # tight TTFT deadline (always missed) + loose (always met)
    reqs = [Request(rid, rng.integers(
        0, cfg.vocab_size, size=int(rng.integers(33, 60)))
        .astype(np.int32), max_new_tokens=4,
        ttft_deadline_ms=0.05 if rid % 2 else 60_000.0,
        itl_deadline_ms=60_000.0)
        for rid in range(4)]
    set_global(tr)
    try:
        for r in reqs:
            eng.submit(r)
        eng.run_to_completion()
    finally:
        set_global(None)
    assert len(eng.completions) == 4

    for c in eng.completions:
        names = [e.name for e in eng.recorder.timeline(c.rid)]
        assert names[0] == "submit" and names[-1] == "finish"
        for must in ("bind", "dispatch", "prefill_chunk",
                     "handoff_stage", "handoff_commit",
                     "first_token"):
            assert must in names, (c.rid, must, names)
        # lifecycle order: admitted before execution; the §4f engine
        # samples the first token at the prefill worker INSIDE the
        # final chunk, so the handoff stages after it and commits
        # before decode continues
        assert names.index("bind") < names.index("prefill_chunk")
        assert names.index("first_token") \
            < names.index("handoff_stage") \
            < names.index("handoff_commit")
        ph = eng.recorder.phases(c.rid)
        assert ph["complete"] is True
        assert ph["ttft_s"] == pytest.approx(c.ttft_s, abs=5e-3)
        # the TTFT window tiles into queue/preempt/exec/wait: the sum
        # never undershoots (wait is the residual) and overshoots only
        # by the final chunk's tail past the first token — the token
        # is sampled INSIDE that chunk, whose full dur counts as
        # pre-first exec — plus any pre-first handoff slice
        tl = eng.recorder.timeline(c.rid)
        t_first = next(e.t for e in tl if e.name == "first_token")
        tail = sum(e.t - t_first for e in tl
                   if e.name in ("prefill_chunk", "resume", "restore")
                   and e.dur is not None
                   and e.t - e.dur <= t_first < e.t)
        s = (ph["queue"] + ph["preempted_pre_first"]
             + ph["prefill_exec"] + ph["prefill_wait"])
        assert s >= ph["ttft_s"] - 1e-6
        assert s <= ph["ttft_s"] + tail + ph["handoff"] + 1e-6

    s = eng.stats()
    assert s["slo"]["requests"] == 4 and s["slo"]["met"] == 2
    assert s["slo"]["goodput"] == pytest.approx(0.5)
    assert s["slo"]["ttft_misses"] == 2
    rep = build_report(eng)
    assert rep["goodput"] == pytest.approx(0.5)
    assert sum(rep["blame"].values()) == 2
    assert rep["blame"]["unattributed"] == 0
    assert set(rep["blame"]) == set(BLAME_PHASES) | {"unattributed"}
    assert len(rep["per_request"]) == 4
    assert all(v["phases"]["complete"] for v in rep["per_request"])
    assert verify_roundtrip(eng.metrics) == []
    # the recorded exec durs reconcile with the traced span durs that
    # wrap the same boundaries (the §10 cross-check serve_bench --slo
    # asserts at scale)
    fr_exec = sum(e.dur for c in eng.completions
                  for e in eng.recorder.timeline(c.rid)
                  if e.name in ("prefill_chunk", "resume", "restore")
                  and e.dur is not None)
    span_exec = sum(r.dur for r in tr.records()
                    if r.subsystem == "engine"
                    and r.name in ("prefill_chunk", "resume",
                                   "restore") and r.dur is not None)
    assert fr_exec == pytest.approx(span_exec, rel=0.05)


def test_engine_without_recorder_has_null_recorder():
    cfg = configs.get_reduced("yi-6b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    eng = make_engine(params, cfg, engine="chunked", slots=2,
                      max_len=64, prefill_buckets=(32,), page_size=16,
                      n_pages=16, chunk_size=32)
    assert eng.recorder is NULL_RECORDER
    eng.submit(Request(0, np.arange(10, dtype=np.int32),
                       max_new_tokens=2))
    eng.run_to_completion()
    assert eng.recorder.rids() == ()
    # no deadlines -> nothing tracked, no slo block in stats
    assert "slo" not in eng.stats()
    assert eng.slo_verdicts == {}
