"""Parcels: message-driven work transport, lowered to TPU collectives.

Paper, Sec. II: "Parcels are the remote semantic equivalent to creating
a local HPX-thread. ... Parcels are either used to move the work to the
data ... or to gather small pieces of data back to the caller."

A `Parcel` here is a *descriptor*: (destination object, action id,
continuation, payload refs).  The host dataflow engine executes parcels
directly (action-manager semantics: local -> run, remote -> enqueue at
destination locality).  The compiled engine *lowers batches of parcels*
into jax collectives:

* same-pattern point-to-point parcels (halo exchange) -> `lax.ppermute`
* all-pairs redistribution (MoE dispatch, AGAS migration) -> `all_to_all`
  or gather/scatter permutations
* reductions back to a caller -> `psum` / `psum_scatter`

`lower_halo_parcels` and `migration_plan` are the two lowering entry
points used by amr/compiled.py and ft/straggler.py.
"""

from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.agas import AGAS, GlobalAddress
from repro.obs import trace as _trace


@dataclasses.dataclass(frozen=True)
class Parcel:
    """An active message.

    Attributes:
      target:  global address of the object the action is applied to.
      action:  action id (a registered callable name or opaque tag).
      args:    payload (small data moved with the parcel).
      continuation: optional global address of an LCO to set with the
        action's result ("gather small pieces of data back").
    """

    target: GlobalAddress
    action: str
    args: tuple = ()
    continuation: Optional[GlobalAddress] = None


class ActionRegistry:
    """Named remotable actions (the paper's component actions)."""

    def __init__(self):
        self._actions: Dict[str, Callable] = {}

    def register(self, name: str) -> Callable[[Callable], Callable]:
        def deco(fn: Callable) -> Callable:
            if name in self._actions:
                raise ValueError(f"action {name!r} already registered")
            self._actions[name] = fn
            return fn
        return deco

    def __getitem__(self, name: str) -> Callable:
        return self._actions[name]

    def __contains__(self, name: str) -> bool:
        return name in self._actions


class ParcelPort:
    """Host-engine parcel port: per-locality inbound queues (paper Fig 1).

    The action manager (`drain`) decodes parcels and runs the action
    where the target lives — exactly the local/remote decision path of
    the HPX architecture walkthrough.
    """

    def __init__(self, agas: AGAS, registry: ActionRegistry):
        self.agas = agas
        self.registry = registry
        self.queues: List[List[Parcel]] = [[] for _ in range(len(agas.domain))]
        self.sent = 0          # performance counters
        self.local_applied = 0

    def apply(self, parcel: Parcel, from_locality: int, state: Any) -> None:
        """Action-manager entry: run locally or send a parcel."""
        if self.agas.is_local(parcel.target, from_locality):
            self.local_applied += 1
            _trace.GLOBAL.instant("parcels", "local_apply",
                                  action=parcel.action)
            self._run(parcel, state)
        else:
            self.sent += 1
            _trace.GLOBAL.instant("parcels", "send", action=parcel.action,
                                  dst=self.agas.locality_of(parcel.target))
            self.queues[self.agas.locality_of(parcel.target)].append(parcel)

    def post(self, parcel: Parcel, dst: int, from_locality: int,
             state: Any) -> None:
        """Action-manager entry with an EXPLICIT destination locality.

        `apply` routes by looking the target up in the directory; that
        requires the target object to exist.  Some parcels move work
        to a locality where their object does not exist YET — the
        first chunk of a cold prompt allocates its pages at the
        destination (its `target` may be None) — so the dispatcher
        resolves the destination itself (prefix-owner or
        least-loaded) and posts here."""
        if dst == from_locality:
            self.local_applied += 1
            _trace.GLOBAL.instant("parcels", "local_apply",
                                  action=parcel.action)
            self._run(parcel, state)
        else:
            self.sent += 1
            _trace.GLOBAL.instant("parcels", "send",
                                  action=parcel.action, dst=dst)
            self.queues[dst].append(parcel)

    def drain(self, locality: int, state: Any) -> int:
        """Process the inbound queue of one locality; returns #parcels."""
        q, self.queues[locality] = self.queues[locality], []
        if not q:
            return 0
        with _trace.GLOBAL.span("parcels", "drain", kind="parcel",
                                lane=locality, n=len(q)):
            for p in q:
                self._run(p, state)
        return len(q)

    def _run(self, parcel: Parcel, state: Any) -> None:
        fn = self.registry[parcel.action]
        result = fn(state, parcel.target, *parcel.args)
        if parcel.continuation is not None:
            state.lcos[parcel.continuation.gid].set(result)


# ---------------------------------------------------------------------------
# Compiled lowerings
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class HaloLowering:
    """A batch of same-shaped p2p parcels lowered to ppermute legs.

    Each leg is one `lax.ppermute` call: `perm[i]` is the list of
    (src_locality, dst_locality) pairs, and `slot_src[i]` / `slot_dst[i]`
    give, per destination locality, which local pool slot the payload is
    read from / written to.  Legs partition the parcels so that within a
    leg every locality sends to at most one peer (ppermute's contract).
    """

    perms: tuple            # tuple of tuple[(src, dst), ...]
    gather_slots: tuple     # per leg: np.ndarray [n_localities] src slot
    scatter_slots: tuple    # per leg: np.ndarray [n_localities] dst slot
    n_parcels: int


def lower_halo_parcels(
    edges: Sequence[Tuple[GlobalAddress, GlobalAddress]],
    agas: AGAS,
) -> HaloLowering:
    """Lower (src_block -> dst_block) payload parcels to ppermute legs.

    Greedy edge-colouring: repeatedly take a maximal set of edges whose
    (src locality, dst locality) are each used at most once; every colour
    class becomes one ppermute leg.  Local edges (src and dst on the same
    locality) are returned in leg form too (ppermute with i->i pairs),
    because on-device they compile to a copy, keeping the lowering
    uniform.
    """
    n_loc = len(agas.domain)
    remaining = [
        (agas.lookup(s), agas.lookup(d)) for s, d in edges
    ]  # [((sloc, sslot), (dloc, dslot))]
    perms, gathers, scatters = [], [], []
    while remaining:
        used_src, used_dst = set(), set()
        leg, rest = [], []
        for (sloc, sslot), (dloc, dslot) in remaining:
            if sloc in used_src or dloc in used_dst:
                rest.append(((sloc, sslot), (dloc, dslot)))
            else:
                used_src.add(sloc)
                used_dst.add(dloc)
                leg.append(((sloc, sslot), (dloc, dslot)))
        remaining = rest
        perm = tuple((sloc, dloc) for (sloc, _), (dloc, _) in leg)
        gs = np.zeros(n_loc, np.int32)
        ss = np.zeros(n_loc, np.int32)
        for (sloc, sslot), (dloc, dslot) in leg:
            gs[sloc] = sslot
            ss[dloc] = dslot
        perms.append(perm)
        gathers.append(gs)
        scatters.append(ss)
    return HaloLowering(tuple(perms), tuple(gathers), tuple(scatters),
                        n_parcels=len(edges))


@dataclasses.dataclass(frozen=True)
class MigrationPlan:
    """AGAS migration lowered to a permutation of the block pool.

    `src_locality/src_slot -> dst_locality/dst_slot` for each moved gid,
    grouped into ppermute legs like halo parcels.  Applied between
    compiled steps by ft/straggler.py.
    """

    moves: tuple  # ((gid, src_loc, src_slot, dst_loc, dst_slot), ...)
    lowering: HaloLowering


def migration_plan(agas: AGAS, moves: Dict[GlobalAddress, int]) -> MigrationPlan:
    """Plan (and commit to the directory) a set of migrations.

    Commits directory updates eagerly — the payload permutation encoded
    in `lowering` must then be applied to the data arrays to restore
    consistency (tested by tests/test_agas.py round-trips).
    """
    recs = []
    # Snapshot sources before committing, then migrate one by one.
    with _trace.GLOBAL.span("parcels", "migration_plan", kind="parcel",
                            moves=len(moves)) as sp:
        for addr, new_loc in sorted(moves.items(), key=lambda kv: kv[0].gid):
            src_loc, src_slot = agas.lookup(addr)
            if src_loc == new_loc:
                continue
            agas.migrate(addr, new_loc)
            dst_loc, dst_slot = agas.lookup(addr)
            recs.append((addr.gid, src_loc, src_slot, dst_loc, dst_slot))
        lowered = _lower_moves(recs, len(agas.domain))
        sp.args["gids"] = [r[0] for r in recs]
    return MigrationPlan(tuple(recs), lowered)


def _lower_moves(recs, n_loc) -> HaloLowering:
    remaining = [((r[1], r[2]), (r[3], r[4])) for r in recs]
    perms, gathers, scatters = [], [], []
    while remaining:
        used_src, used_dst = set(), set()
        leg, rest = [], []
        for e in remaining:
            (sloc, _), (dloc, _) = e
            if sloc in used_src or dloc in used_dst:
                rest.append(e)
            else:
                used_src.add(sloc)
                used_dst.add(dloc)
                leg.append(e)
        remaining = rest
        perm = tuple((s[0], d[0]) for s, d in leg)
        gs = np.zeros(n_loc, np.int32)
        ss = np.zeros(n_loc, np.int32)
        for (sloc, sslot), (dloc, dslot) in leg:
            gs[sloc] = sslot
            ss[dloc] = dslot
        perms.append(perm)
        gathers.append(gs)
        scatters.append(ss)
    return HaloLowering(tuple(perms), tuple(gathers), tuple(scatters),
                        n_parcels=len(recs))


@dataclasses.dataclass(frozen=True)
class PrefillParcel:
    """One prefill chunk as an active message (DESIGN.md §4f).

    The serving rendering of "move the work to the data": a chunk of
    prompt [start, start+take) for request `rid` in engine slot
    `slot`, dispatched to `locality` — the AGAS locality owning the
    prompt's radix-matched prefix pages (`anchor` is the deepest
    matched page, or the slot's last resident page for chunks after
    the first), or the least-loaded prefill worker when the prompt is
    cold (`anchor` None: there is no data yet; the chunk's pages are
    allocated at the destination, so the NEXT prompt sharing this
    prefix finds an owner)."""

    rid: int
    slot: int
    start: int
    take: int
    anchor: Optional[GlobalAddress]
    locality: int


@dataclasses.dataclass(frozen=True)
class PrefillLowering:
    """A step's prefill parcels grouped per destination locality,
    each batch padded to the canonical power-of-two size class — the
    same trick `plan_move_arrays` uses, so a compiled dispatch
    program exists per (locality, size class), never per step."""

    batches: tuple      # ((locality, (PrefillParcel, ...)), ...)
    sizes: tuple        # canonical (padded) batch size per destination
    n_parcels: int


def lower_prefill_parcels(parcels: Sequence[PrefillParcel]
                          ) -> PrefillLowering:
    """Group one step's prefill parcels by destination and pad each
    batch to `canonical_size` — the batched-dispatch lowering."""
    by_dst: Dict[int, List[PrefillParcel]] = defaultdict(list)
    for p in parcels:
        by_dst[p.locality].append(p)
    batches = tuple((loc, tuple(by_dst[loc]))
                    for loc in sorted(by_dst))
    sizes = tuple(canonical_size(len(b)) for _, b in batches)
    return PrefillLowering(batches, sizes, len(parcels))


def canonical_size(n: int) -> int:
    """Smallest power of two >= n (and >= 1).

    Permutation and transfer programs are compiled at canonical batch
    sizes: padding a move list up to the next power of two with
    identity moves onto a scratch slot means one compiled program per
    size class instead of one per exact count — the production-pool
    fix DESIGN.md §9.4 called for.
    """
    p = 1
    while p < n:
        p <<= 1
    return p


def plan_move_arrays(plan: MigrationPlan, pad_to: Optional[int] = None,
                     pad_move: Tuple[int, int] = (0, 0)
                     ) -> Tuple[np.ndarray, np.ndarray,
                                np.ndarray, np.ndarray]:
    """(src_loc, src_slot, dst_loc, dst_slot) int32 arrays of a plan.

    This is the single-device lowering of the plan's ppermute legs:
    applied as ONE gather-before-scatter permutation
    (``arr.at[:, dst_loc, dst_slot].set(arr[:, src_loc, src_slot])``),
    every payload is read from the pre-plan array before any
    destination is written, so the move order inside the legs cannot
    matter — exactly the semantics the legged ppermute execution has
    when each leg gathers from a snapshot of the source pool.

    `pad_to` pads the arrays to a canonical length with identity
    self-moves of `pad_move` = (locality, slot) — point it at a
    scratch slot (the page pool's null row) and the padded entries
    copy that slot onto itself, so one compiled permutation program
    serves every plan in the size class.
    """
    moves = np.array([m[1:] for m in plan.moves],
                     np.int32).reshape(-1, 4)
    if pad_to is not None and pad_to > len(moves):
        loc, slot = pad_move
        fill = np.tile(np.array([loc, slot, loc, slot], np.int32),
                       (pad_to - len(moves), 1))
        moves = np.concatenate([moves, fill], axis=0)
    return moves[:, 0], moves[:, 1], moves[:, 2], moves[:, 3]


def parcel_traffic_bytes(lowering: HaloLowering, payload_bytes: int) -> dict:
    """Traffic accounting for the roofline collective term."""
    inter = sum(
        1 for perm in lowering.perms for (s, d) in perm if s != d
    )
    intra = lowering.n_parcels - inter
    return {
        "parcels": lowering.n_parcels,
        "inter_locality": inter,
        "intra_locality": intra,
        "bytes_on_wire": inter * payload_bytes,
        "legs": len(lowering.perms),
    }
